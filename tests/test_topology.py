"""Communication-fabric subsystem invariants: mixing matrices, D-Cliques
label balance, topology schedules (constant wrapper, time-varying
D-Cliques, random matchings), the D-PSGD/BSP equivalence on the complete
graph, compile-once gossip under changing neighbor sets, the Pallas
neighbor_mix kernel vs its dense oracle, and CommLedger conservation +
re-wiring accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CommConfig, FabricConfig
from repro.core.algorithms.base import ModelFns, tree_size
from repro.core.algorithms.bsp import BSP
from repro.core.algorithms.dpsgd import DPSGD
from repro.kernels import ops, ref
from repro.topology import (LINK_PROFILES, CommLedger, TopologySchedule,
                            as_schedule, build_schedule, build_topology,
                            constant_schedule, d_cliques, fully_connected,
                            hierarchical, random_matching_schedule,
                            random_regular, ring, topology_ladder, torus,
                            time_varying_d_cliques)

K = 4
DIM = 8


def exclusive_hist(n_nodes: int, n_classes: int) -> np.ndarray:
    """Exclusive-label histogram: node k holds only class k % C."""
    hist = np.zeros((n_nodes, n_classes))
    for k in range(n_nodes):
        hist[k, k % n_classes] = 100
    return hist


# ---------------------------------------------------------------------------
# graphs & mixing matrices
# ---------------------------------------------------------------------------

ALL_TOPOLOGIES = [fully_connected(5), ring(5), ring(2), torus(6), torus(9),
                  random_regular(8, 3, seed=0), hierarchical(6),
                  hierarchical(9, n_datacenters=3)]


@pytest.mark.parametrize("topo", ALL_TOPOLOGIES, ids=lambda t: t.name)
def test_mixing_matrix_doubly_stochastic_symmetric(topo):
    W = topo.mixing
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)
    assert (W >= -1e-12).all()
    # supported only on edges + diagonal
    edge_set = set(topo.edges)
    for i in range(topo.n_nodes):
        for j in range(i + 1, topo.n_nodes):
            if (i, j) not in edge_set:
                assert W[i, j] == 0.0


@pytest.mark.parametrize("topo", ALL_TOPOLOGIES, ids=lambda t: t.name)
def test_gossip_converges_to_consensus(topo):
    """W^t x -> mean(x): doubly-stochastic + connected + positive gap."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=topo.n_nodes)
    y = np.linalg.matrix_power(topo.mixing, 200) @ x
    np.testing.assert_allclose(y, x.mean(), atol=1e-6)
    assert topo.spectral_gap() > 0.01


def test_fully_connected_mixing_is_uniform():
    topo = fully_connected(5)
    np.testing.assert_allclose(topo.mixing, np.full((5, 5), 0.2), atol=1e-12)


def test_neighbor_arrays_reconstruct_mixing():
    topo = random_regular(8, 3, seed=1)
    idx, w, sw = topo.neighbor_arrays()
    K = topo.n_nodes
    R = np.zeros((K, K))
    for k in range(K):
        R[k, k] += sw[k]
        for d in range(idx.shape[1]):
            R[k, idx[k, d]] += w[k, d]
    np.testing.assert_allclose(R, topo.mixing, atol=1e-6)


def test_hierarchical_marks_wan_edges():
    topo = hierarchical(9, n_datacenters=3)
    wan = topo.wan_edge_indices()
    assert len(wan) == 3                      # gateway triangle
    assert len(topo.cliques) == 3
    lan = [e for e in range(len(topo.edges)) if e not in set(wan)]
    # LAN edges stay inside one datacenter
    groups = [set(c) for c in topo.cliques]
    for e in lan:
        i, j = topo.edges[e]
        assert any(i in g and j in g for g in groups)


def test_dcliques_label_histograms_near_uniform():
    """Exclusive-label partition over 10 nodes / 5 classes: each greedy
    clique should recover a (near-)uniform aggregate histogram."""
    n_nodes, n_classes = 10, 5
    hist = np.zeros((n_nodes, n_classes))
    for k in range(n_nodes):
        hist[k, k % n_classes] = 100
    topo = d_cliques(hist, seed=0)
    assert len(topo.cliques) >= 2
    glob = hist.sum(0) / hist.sum()
    for cq in topo.cliques:
        s = hist[list(cq)].sum(0)
        tv = 0.5 * np.abs(s / s.sum() - glob).sum()
        assert tv < 0.11, (cq, s)
    assert len(topo.wan_edge_indices()) >= 1   # inter-clique ring is WAN


def test_build_topology_registry():
    for name in ("full", "ring", "torus", "random", "geo-wan"):
        topo = build_topology(name, 6)
        assert topo.n_nodes == 6
    with pytest.raises(ValueError):
        build_topology("moebius", 6)
    with pytest.raises(AssertionError):
        build_topology("dcliques", 6)          # needs label_hist


@pytest.mark.parametrize("topo", ALL_TOPOLOGIES, ids=lambda t: t.name)
def test_neighbors_adjacency_cache_matches_edge_scan(topo):
    """neighbors() is served from the cached adjacency — it must agree
    with a brute-force scan of the edge list."""
    for k in range(topo.n_nodes):
        scan = sorted([j for i, j in topo.edges if i == k]
                      + [i for i, j in topo.edges if j == k])
        assert topo.neighbors(k) == scan
    np.testing.assert_array_equal(
        topo.degrees(), [len(topo.neighbors(k))
                         for k in range(topo.n_nodes)])


# ---------------------------------------------------------------------------
# topology schedules
# ---------------------------------------------------------------------------

def test_constant_schedule_is_the_old_single_graph_path():
    topo = hierarchical(9, n_datacenters=3)
    sched = constant_schedule(topo)
    assert sched.is_constant and sched.period == 1
    assert sched.at(0) is topo and sched.at(17) is topo
    assert sched.max_degree == topo.max_degree
    assert sched.spectral_gap() == pytest.approx(topo.spectral_gap(),
                                                 abs=1e-9)
    assert set(sched.union().edges) == set(topo.edges)
    assert as_schedule(topo).at(0) is topo
    assert as_schedule(sched) is sched


def test_tv_dcliques_round_structure():
    """One-peer-per-round: every round is a near-perfect matching inside
    each clique plus a single rotating WAN inter-clique edge."""
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    assert isinstance(sched, TopologySchedule) and not sched.is_constant
    const = d_cliques(exclusive_hist(9, 3), seed=0)
    n_cliques = len(const.cliques)
    assert n_cliques >= 3
    for r in range(sched.period):
        g = sched.at(r)
        assert g.max_degree <= 2            # one peer + maybe the WAN hop
        assert len(g.wan_edge_indices()) == 1
        # per-round mixing is still symmetric doubly-stochastic
        W = g.mixing
        np.testing.assert_allclose(W, W.T, atol=1e-12)
        np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)
    # the union over one period recovers the constant D-Cliques graph
    assert set(sched.union().edges) == set(const.edges)
    # and the effective per-round mixing rate survives the sparsification
    assert sched.spectral_gap() > 0.5 * const.spectral_gap()


def test_tv_dcliques_strictly_fewer_edges_per_round():
    hist = exclusive_hist(10, 5)
    sched = time_varying_d_cliques(hist, seed=0)
    const = d_cliques(hist, seed=0)
    for r in range(sched.period):
        assert len(sched.at(r).edges) < len(const.edges)


def test_random_matching_schedule_structure():
    sched = random_matching_schedule(10, seed=0)
    for r in range(sched.period):
        g = sched.at(r)
        assert g.max_degree <= 1            # matchings only
        deg = g.degrees()
        assert deg.sum() == 2 * len(g.edges)
    assert sched.spectral_gap() > 0.05      # union mixes
    # placement-blind matchings cross datacenter sites: WAN is priced,
    # not structurally zero (cross-site iff k % n_sites differs)
    assert any(len(sched.at(r).wan_edge_indices()) > 0
               for r in range(sched.period))
    for r in range(sched.period):
        g = sched.at(r)
        for e, (i, j) in enumerate(g.edges):
            crosses = (i % 3) != (j % 3)    # sqrt(10) -> 3 sites
            assert (g.edge_class[e] == "wan") == crosses
    # a single-LAN cluster stays WAN-free
    lan_only = random_matching_schedule(10, seed=0, n_sites=1)
    assert all(len(lan_only.at(r).wan_edge_indices()) == 0
               for r in range(lan_only.period))


def test_schedule_neighbor_arrays_share_one_shape():
    """Every round's operands are padded to the schedule-wide max degree
    — the property that keeps the jitted gossip step compiled once."""
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    shapes = set()
    for r in range(sched.period):
        idx, w, sw = sched.neighbor_arrays(r)
        shapes.add((idx.shape, w.shape, sw.shape))
        # padded entries are self-loops with zero weight
        assert (w >= 0).all()
    assert len(shapes) == 1
    (ishape, wshape, sshape), = shapes
    assert ishape == (9, sched.max_degree)


def test_schedule_round_vs_effective_spectral_gap():
    """A single matching round does not mix (gap 0); the period does."""
    sched = random_matching_schedule(8, seed=1)
    assert any(sched.round_spectral_gap(r) == pytest.approx(0.0, abs=1e-9)
               for r in range(sched.period))
    assert sched.spectral_gap() > 0.0


def test_build_schedule_registry():
    hist = exclusive_hist(9, 3)
    assert build_schedule("ring", 6).is_constant
    assert build_schedule("full", 6).at(0).name == "full"
    assert not build_schedule("tv-dcliques", 9, label_hist=hist).is_constant
    assert not build_schedule("random-matching", 8).is_constant
    assert build_schedule("dcliques", 9, label_hist=hist).is_constant
    with pytest.raises(AssertionError):
        build_schedule("tv-dcliques", 9)    # needs label_hist
    with pytest.raises(ValueError):
        build_schedule("moebius", 6)


def test_topology_ladder_densest_first():
    """SkewScout rungs follow the THETA_LADDERS convention: index 0 is
    the most communication-heavy fabric."""
    lad = topology_ladder(9, label_hist=exclusive_hist(9, 3))
    assert lad[0].at(0).name == "full"
    # the cheapest rung is the time-varying one — fewer per-round edges
    # than even a ring (hill climbing needs the ladder monotone in cost)
    assert lad[-1].name == "tv-dcliques"
    mean_edges = [np.mean([len(s.at(r).edges) for r in range(s.period)])
                  for s in lad]
    assert all(a > b for a, b in zip(mean_edges, mean_edges[1:])), \
        mean_edges
    # without label histograms the label-aware rung degrades gracefully
    lad2 = topology_ladder(9)
    assert all(s.is_constant for s in lad2)
    assert lad2[-1].at(0).name == "ring"


# ---------------------------------------------------------------------------
# neighbor_mix kernel vs dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", [ring(5), random_regular(8, 3, seed=1),
                                  hierarchical(6), fully_connected(4)],
                         ids=lambda t: t.name)
@pytest.mark.parametrize("n", [37, 1000, 8192 + 13])
def test_neighbor_mix_matches_dense_ref(topo, n):
    x = jax.random.normal(jax.random.PRNGKey(0), (topo.n_nodes, n))
    idx, w, sw = topo.neighbor_arrays()
    out = ops.neighbor_mix(x, jnp.asarray(idx), jnp.asarray(w),
                           jnp.asarray(sw))
    expect = ref.neighbor_mix_ref(x, jnp.asarray(topo.mixing, jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# D-PSGD semantics
# ---------------------------------------------------------------------------

def make_quadratic_fns():
    def loss_and_grad(params, mstate, batch):
        diff = params["w"] - batch["target"]
        return 0.5 * jnp.sum(diff ** 2), {"w": diff}, mstate
    return ModelFns(loss_and_grad=loss_and_grad)


@pytest.fixture
def setup():
    fns = make_quadratic_fns()
    params = {"w": jnp.zeros((DIM,))}
    mstate = {"dummy": jnp.zeros((1,))}
    targets = np.stack([np.full(DIM, float(k + 1)) for k in range(K)])
    return fns, params, mstate, {"target": jnp.asarray(targets)}


def test_dpsgd_complete_graph_equals_bsp(setup):
    """Uniform mixing restores exact consensus every step, so the
    trajectory coincides with BSP (momentum included)."""
    fns, params, mstate, batch = setup
    bsp = BSP(fns, K, momentum=0.9, weight_decay=0.0)
    dp = DPSGD(fns, K, topology=fully_connected(K), momentum=0.9,
               weight_decay=0.0)
    sb, sd = bsp.init(params, mstate), dp.init(params, mstate)
    for t in range(10):
        sb, _ = bsp.step(sb, batch, jnp.float32(0.05), jnp.int32(t))
        sd, m = dp.step(sd, batch, jnp.float32(0.05), jnp.int32(t))
    wb = np.asarray(sb["params"]["w"])
    wd = np.asarray(sd["params"]["w"])
    np.testing.assert_allclose(wd, np.broadcast_to(wb, wd.shape), atol=1e-5)
    assert float(m["consensus_delta"]) < 1e-6


def test_dpsgd_two_node_ring_equals_bsp(setup):
    """K=2 ring mixing is exact averaging — the synthetic 2-node
    benchmark where dpsgd must reproduce BSP."""
    fns, params, mstate, _ = setup
    targets = np.stack([np.full(DIM, 1.0), np.full(DIM, 3.0)])
    batch = {"target": jnp.asarray(targets)}
    bsp = BSP(fns, 2, momentum=0.9, weight_decay=0.0)
    dp = DPSGD(fns, 2, topology=ring(2), momentum=0.9, weight_decay=0.0)
    sb, sd = bsp.init(params, mstate), dp.init(params, mstate)
    for t in range(20):
        sb, _ = bsp.step(sb, batch, jnp.float32(0.05), jnp.int32(t))
        sd, _ = dp.step(sd, batch, jnp.float32(0.05), jnp.int32(t))
    pb, _ = bsp.eval_params(sb)
    pd, _ = dp.eval_params(sd)
    np.testing.assert_allclose(np.asarray(pd["w"]), np.asarray(pb["w"]),
                               atol=1e-5)


def test_dpsgd_ring_reaches_consensus_on_mean_target(setup):
    """Sparse-graph gossip settles in an O(lr)-neighborhood of the
    global optimum (Lian et al. Thm 1) — shrink lr, shrink the error."""
    fns, params, mstate, batch = setup
    errs = {}
    for lr in (0.05, 0.01):
        dp = DPSGD(fns, K, topology=ring(K), momentum=0.0)
        s = dp.init(params, mstate)
        for t in range(1500):
            s, m = dp.step(s, batch, jnp.float32(lr), jnp.int32(t))
        w = np.asarray(s["params"]["w"])
        mean_target = np.mean([k + 1 for k in range(K)])
        errs[lr] = np.abs(w - mean_target).max()
    assert errs[0.05] < 0.05 and errs[0.01] < 0.01, errs
    assert errs[0.01] < errs[0.05]


def test_dpsgd_comm_floats_scale_with_degree(setup):
    fns, params, mstate, batch = setup
    per_model = tree_size(params)
    for topo in (ring(K), fully_connected(K)):
        dp = DPSGD(fns, K, topology=topo, momentum=0.0)
        s = dp.init(params, mstate)
        _, m = dp.step(s, batch, jnp.float32(0.05), jnp.int32(0))
        assert float(m["comm_floats"]) == pytest.approx(
            topo.mean_degree * per_model)


def test_dpsgd_kernel_and_dense_mix_agree(setup):
    fns, params, mstate, batch = setup
    topo = ring(K)
    dp_k = DPSGD(fns, K, topology=topo, momentum=0.9, use_kernel=True)
    dp_d = DPSGD(fns, K, topology=topo, momentum=0.9, use_kernel=False)
    sk, sd = dp_k.init(params, mstate), dp_d.init(params, mstate)
    for t in range(5):
        sk, _ = dp_k.step(sk, batch, jnp.float32(0.05), jnp.int32(t))
        sd, _ = dp_d.step(sd, batch, jnp.float32(0.05), jnp.int32(t))
    np.testing.assert_allclose(np.asarray(sk["params"]["w"]),
                               np.asarray(sd["params"]["w"]), atol=1e-5)


# ---------------------------------------------------------------------------
# D-PSGD on schedules: per-round operands, one compilation
# ---------------------------------------------------------------------------

def make_nine_node_batch():
    targets = np.stack([np.full(DIM, float(k + 1)) for k in range(9)])
    return {"target": jnp.asarray(targets)}


def test_dpsgd_schedule_compiles_once_across_changing_neighbors():
    """Acceptance: neighbor indices/weights are runtime operands of the
    jitted step — a schedule that changes the neighbor set every round
    must not retrace (trace_count counts trace-time executions)."""
    fns = make_quadratic_fns()
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    dp = DPSGD(fns, 9, topology=sched, momentum=0.9)
    s = dp.init({"w": jnp.zeros((DIM,))}, {"dummy": jnp.zeros((1,))})
    batch = make_nine_node_batch()
    seen_idx = set()
    for t in range(2 * sched.period):
        s, m = dp.step(s, batch, jnp.float32(0.05), jnp.int32(t))
        seen_idx.add(np.asarray(dp.mix_operands(t)[0]).tobytes())
    assert len(seen_idx) > 1, "schedule never changed the neighbor set"
    assert dp.trace_count == 1, \
        f"gossip step retraced {dp.trace_count}x across the schedule"


def test_dpsgd_rung_switch_reuses_compilation():
    """Switching SkewScout topology rungs mid-run keeps the padded
    operand shape (pad_degree = ladder max), so no retrace either."""
    fns = make_quadratic_fns()
    lad = topology_ladder(9, label_hist=exclusive_hist(9, 3))
    pad = max(s.max_degree for s in lad)
    dp = DPSGD(fns, 9, topology=lad[-1], momentum=0.9, pad_degree=pad)
    s = dp.init({"w": jnp.zeros((DIM,))}, {"dummy": jnp.zeros((1,))})
    batch = make_nine_node_batch()
    t = 0
    for rung in reversed(lad):              # ring -> ... -> full
        dp.set_schedule(rung)
        for _ in range(3):
            s, _ = dp.step(s, batch, jnp.float32(0.05), jnp.int32(t))
            t += 1
    assert dp.trace_count == 1


def test_dpsgd_comm_floats_track_round_degree():
    """comm_floats is derived from the *runtime* weights, so it follows
    each round's active degree, not a frozen trace-time constant."""
    fns = make_quadratic_fns()
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    dp = DPSGD(fns, 9, topology=sched, momentum=0.0)
    s = dp.init({"w": jnp.zeros((DIM,))}, {"dummy": jnp.zeros((1,))})
    batch = make_nine_node_batch()
    per_model = DIM
    for t in range(sched.period):
        s, m = dp.step(s, batch, jnp.float32(0.01), jnp.int32(t))
        assert float(m["comm_floats"]) == pytest.approx(
            sched.at(t).mean_degree * per_model, rel=1e-5)


def test_dpsgd_tv_schedule_converges_to_consensus():
    """Gossip over the time-varying fabric settles in an
    O(lr/spectral-gap)-neighborhood of the global optimum (Lian et al.
    Thm 1) — shrink lr, shrink the error.  The neighborhood is wider
    than a static ring's because each round only mixes a matching."""
    fns = make_quadratic_fns()
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    batch = make_nine_node_batch()
    mean_target = np.mean([k + 1 for k in range(9)])
    errs = {}
    for lr in (0.05, 0.01):
        dp = DPSGD(fns, 9, topology=sched, momentum=0.0)
        s = dp.init({"w": jnp.zeros((DIM,))}, {"dummy": jnp.zeros((1,))})
        for t in range(1500):
            s, m = dp.step(s, batch, jnp.float32(lr), jnp.int32(t))
        errs[lr] = np.abs(np.asarray(s["params"]["w"])
                          - mean_target).max()
    assert errs[0.05] < 0.8 and errs[0.01] < 0.25, errs
    assert errs[0.01] < errs[0.05]


# ---------------------------------------------------------------------------
# CommLedger
# ---------------------------------------------------------------------------

def test_ledger_exchange_conserves_floats():
    topo = hierarchical(9, n_datacenters=3)
    led = CommLedger(topo, LINK_PROFILES["geo-wan"])
    led.record_exchange(1000.0)
    # every node's floats land somewhere: total == K * c, split LAN/WAN
    assert led.view().total_floats == pytest.approx(9 * 1000.0)
    assert led.lan_floats > 0 and led.wan_floats > 0
    assert led.view().total_floats == pytest.approx(
        led.lan_floats + led.wan_floats)


def test_ledger_gossip_traffic_per_edge():
    topo = ring(5)
    led = CommLedger(topo, LINK_PROFILES["uniform"])
    led.record_gossip(100.0)
    # each of the 5 edges carries the model both directions
    v = led.view()
    assert v.total_floats == pytest.approx(5 * 2 * 100.0)
    np.testing.assert_allclose(v.edge_traffic[v.union_eids], 200.0)


def test_ledger_wan_pricing_dominates_under_geo_profile():
    topo = hierarchical(6)
    prof = LINK_PROFILES["geo-wan"]
    led = CommLedger(topo, prof)
    led.record_gossip(1000.0)
    wan_cost = led.wan_floats * prof.price_per_float("wan")
    assert wan_cost / led.view().priced_cost > 0.9   # WAN bytes dominate
    # uniform profile: priced cost is proportional to raw floats
    led_u = CommLedger(topo, LINK_PROFILES["uniform"])
    led_u.record_gossip(1000.0)
    assert led_u.view().priced_cost == pytest.approx(
        led_u.view().total_floats * LINK_PROFILES["uniform"].price_per_float("lan"))


def test_ledger_sim_time_slowest_link():
    topo = hierarchical(6)
    prof = LINK_PROFILES["geo-wan"]
    led = CommLedger(topo, prof)
    led.record_gossip(1000.0)
    expect = prof.wan_latency + 2000.0 / prof.wan_bandwidth
    assert led.sim_time_s == pytest.approx(expect)


def test_ledger_per_node_vector_exchange():
    topo = ring(4)
    led = CommLedger(topo, LINK_PROFILES["uniform"])
    led.record_exchange([100.0, 0.0, 0.0, 0.0])
    v = led.view()
    assert v.total_floats == pytest.approx(100.0)
    # node 0 has two incident edges, 50 floats each
    traffic = v.edge_traffic[v.union_eids]
    np.testing.assert_allclose(traffic[traffic > 0], 50.0)


# ---------------------------------------------------------------------------
# CommLedger invariants: conservation, monotonicity, re-wiring
# ---------------------------------------------------------------------------

def test_ledger_invariant_lan_wan_partition_all_priced_floats():
    """lan_floats + wan_floats must cover every priced float — gossip,
    exchanges, and re-wiring traffic alike."""
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    led = CommLedger(sched, LINK_PROFILES["geo-wan"],
                     config=FabricConfig(rewire_floats=32.0))
    for t in range(2 * sched.period):
        led.record_gossip(500.0, t=t)
        led.record_exchange(40.0)
    assert led.view().total_floats == pytest.approx(
        led.lan_floats + led.wan_floats)
    # per-edge attribution conserves the same total
    v = led.view()
    assert v.edge_traffic[v.union_eids].sum() == pytest.approx(
        v.total_floats)
    assert led.view().rewire_floats > 0
    assert led.view().rewire_floats == pytest.approx(
        led.rewire_lan_floats + led.rewire_wan_floats)
    # rewiring is priced (it is part of priced_cost, not free)
    assert led.view().rewiring_cost > 0
    assert led.view().rewiring_cost < led.view().priced_cost


def test_ledger_sim_time_monotone_nondecreasing():
    sched = random_matching_schedule(8, seed=2)
    led = CommLedger(sched, LINK_PROFILES["geo-wan"],
                     config=FabricConfig(rewire_floats=8.0))
    last = 0.0
    for t in range(3 * sched.period):
        led.record_gossip(100.0, t=t)
        assert led.sim_time_s >= last
        last = led.sim_time_s
        led.record_exchange(10.0)
        assert led.sim_time_s >= last
        last = led.sim_time_s
    assert led.sim_time_s > 0


def test_ledger_rewiring_accounting():
    """Constant schedules never re-wire; time-varying schedules pay
    FabricConfig.rewire_floats for each newly-activated link, and the
    first round establishes the fabric for free."""
    const = CommLedger(ring(6), LINK_PROFILES["uniform"],
                       config=FabricConfig(rewire_floats=100.0))
    for t in range(5):
        const.record_gossip(10.0, t=t)
    assert const.view().rewire_floats == 0.0 and const.rewire_events == 0

    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    led = CommLedger(sched, LINK_PROFILES["uniform"],
                     config=FabricConfig(rewire_floats=100.0))
    led.record_gossip(10.0, t=0)
    assert led.rewire_events == 0            # first activation is free
    base = led.view().total_floats
    led.record_gossip(10.0, t=1)
    new_edges = len(set(sched.at(1).edges) - set(sched.at(0).edges))
    assert new_edges > 0
    assert led.rewire_events == new_edges
    assert led.view().rewire_floats == pytest.approx(100.0 * new_edges)
    assert led.view().total_floats == pytest.approx(
        base + 2 * 10.0 * len(sched.at(1).edges) + 100.0 * new_edges)


def test_ledger_probe_exchange_neither_pays_nor_resets_rewiring():
    """Union-routed exchanges (SkewScout probes) have no per-round edge
    set: they must not be charged re-wiring against the sparse gossip
    round, and must not mask the next round's genuine re-wiring."""
    sched = time_varying_d_cliques(exclusive_hist(9, 3), seed=0)
    led = CommLedger(sched, LINK_PROFILES["uniform"],
                     config=FabricConfig(rewire_floats=100.0))
    led.record_gossip(10.0, t=0)
    led.record_exchange(5.0)                 # probe between rounds
    assert led.rewire_events == 0            # probe did not "re-wire"
    led.record_gossip(10.0, t=1)
    new_edges = len(set(sched.at(1).edges) - set(sched.at(0).edges))
    assert led.rewire_events == new_edges    # rotation still charged


def test_ledger_traffic_by_edge_survives_switch_to_sparser_fabric():
    """edge_traffic is a view on the current union; traffic_by_edge is
    the lossless history — its sum always matches total_floats."""
    led = CommLedger(fully_connected(6), LINK_PROFILES["uniform"])
    led.record_gossip(10.0, t=0)
    led.switch_schedule(ring(6))
    led.record_gossip(10.0, t=0)
    v = led.view()
    assert sum(v.traffic_map().values()) == pytest.approx(v.total_floats)
    # the union selection only shows the ring's edges now
    assert len(v.union_eids) == len(ring(6).edges)
    assert v.edge_traffic[v.union_eids].sum() < v.total_floats


def test_dpsgd_set_schedule_refuses_pad_growth_after_compile():
    """Growing the operand pad after the step compiled would silently
    retrace — set_schedule must refuse once traced."""
    fns = make_quadratic_fns()
    dp = DPSGD(fns, K, topology=ring(K), momentum=0.0)
    s = dp.init({"w": jnp.zeros((DIM,))}, {"dummy": jnp.zeros((1,))})
    batch = {"target": jnp.asarray(
        np.stack([np.full(DIM, float(k)) for k in range(K)]))}
    s, _ = dp.step(s, batch, jnp.float32(0.05), jnp.int32(0))
    dp.set_schedule(ring(K))                 # same degree: fine
    with pytest.raises(AssertionError, match="pad_degree"):
        dp.set_schedule(fully_connected(K))  # would widen the operands


def test_ledger_switch_schedule_charges_rewiring_and_keeps_traffic():
    """SkewScout rung switch: traffic history survives, and the first
    round on the new fabric pays re-wiring for its new links."""
    hist = exclusive_hist(9, 3)
    sparse = time_varying_d_cliques(hist, seed=0)
    dense = fully_connected(9)
    led = CommLedger(sparse, LINK_PROFILES["uniform"],
                     config=FabricConfig(rewire_floats=50.0))
    led.record_gossip(10.0, t=0)
    before = led.view().total_floats
    led.switch_schedule(dense)
    assert led.view().total_floats == pytest.approx(before)   # history kept
    led.record_gossip(10.0, t=1)
    new_edges = len(set(dense.edges) - set(sparse.at(0).edges))
    assert led.rewire_events == new_edges
    assert led.view().total_floats == pytest.approx(
        before + 2 * 10.0 * len(dense.edges) + 50.0 * new_edges)
    assert led.summary()["rewire_floats"] == pytest.approx(
        50.0 * new_edges)


# ---------------------------------------------------------------------------
# end-to-end: dpsgd through the trainer (full topology == BSP quality)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dpsgd_full_topology_matches_bsp_accuracy():
    """Acceptance: dpsgd on a fully-connected topology reproduces BSP's
    validation accuracy within 0.5pp on the synthetic 2-node benchmark."""
    from repro.configs.cnn_zoo import CNN_ZOO
    from repro.core.partition import partition_label_skew
    from repro.core.trainer import train_decentralized
    from repro.data.synthetic import synth_images
    ds = synth_images(1500, seed=0, noise=0.8, class_sep=0.35)
    val = synth_images(500, seed=99, noise=0.8, class_sep=0.35)
    idx = partition_label_skew(ds.y, 2, 0.0, seed=1)
    parts = [(ds.x[i], ds.y[i]) for i in idx]
    kw = dict(steps=200, batch=20, lr=0.02, eval_every=200)
    bsp = train_decentralized(CNN_ZOO["gn-lenet"], "bsp", parts,
                              (val.x, val.y), **kw)
    dp = train_decentralized(CNN_ZOO["gn-lenet"], "dpsgd", parts,
                             (val.x, val.y),
                             comm=CommConfig(
                                 strategy="dpsgd",
                                 fabric=FabricConfig(topology="full")),
                             **kw)
    assert abs(dp.val_acc - bsp.val_acc) < 0.005 + 1e-9, \
        (dp.val_acc, bsp.val_acc)
    assert dp.topology == "full"
    assert dp.extras["ledger"]["total_floats"] > 0


@pytest.mark.slow
def test_tv_dcliques_matches_constant_accuracy_with_fewer_wan_floats():
    """Acceptance: the one-peer-per-round D-Cliques schedule reaches
    accuracy within noise of constant D-Cliques under full label skew,
    while the ledger reports strictly fewer per-round WAN floats."""
    from repro.configs.cnn_zoo import CNN_ZOO
    from repro.core.trainer import train_decentralized
    from repro.data.synthetic import synth_images
    n_nodes, n_classes = 9, 3
    ds = synth_images(1800, seed=0, noise=0.8, class_sep=0.35,
                      n_classes=n_classes)
    val = synth_images(600, seed=99, noise=0.8, class_sep=0.35,
                       n_classes=n_classes)
    parts = []
    for k in range(n_nodes):          # full skew: node k sees one class
        idx = np.where(ds.y == k % n_classes)[0][k // n_classes::3]
        parts.append((ds.x[idx], ds.y[idx]))
    steps = 150
    kw = dict(steps=steps, batch=10, lr=0.02, eval_every=steps)
    runs = {}
    for name in ("dcliques", "tv-dcliques"):
        runs[name] = train_decentralized(
            CNN_ZOO["gn-lenet"], "dpsgd", parts, (val.x, val.y),
            comm=CommConfig(strategy="dpsgd",
                            fabric=FabricConfig(topology=name,
                                                profile="geo-wan")),
            **kw)
    const, tv = runs["dcliques"], runs["tv-dcliques"]
    # within noise of the constant variant
    assert tv.val_acc > const.val_acc - 0.06, \
        (tv.val_acc, const.val_acc)
    # strictly fewer per-round WAN floats (3 cliques: 3 WAN edges -> 1)
    assert tv.comm_wan_floats / steps < const.comm_wan_floats / steps, \
        (tv.comm_wan_floats, const.comm_wan_floats)
    # and strictly less total traffic (matchings vs intra-clique meshes)
    assert tv.comm_lan_floats < const.comm_lan_floats
    assert tv.extras["schedule_period"] > 1
    assert const.extras["schedule_period"] == 1


def test_trainer_rejects_invalid_eval_schedule():
    from repro.configs.cnn_zoo import CNN_ZOO
    from repro.core.trainer import train_decentralized
    from repro.data.synthetic import synth_images
    ds = synth_images(100, seed=0)
    parts = [(ds.x[:50], ds.y[:50]), (ds.x[50:], ds.y[50:])]
    with pytest.raises(ValueError, match="steps"):
        train_decentralized(CNN_ZOO["gn-lenet"], "bsp", parts,
                            (ds.x, ds.y), steps=0)
    with pytest.raises(ValueError, match="eval_every"):
        train_decentralized(CNN_ZOO["gn-lenet"], "bsp", parts,
                            (ds.x, ds.y), steps=10, eval_every=0)


def test_make_algorithm_rejects_label_aware_topology_without_hist():
    """Standalone dpsgd fallback must not silently build a label-blind
    graph when the config asks for a label-aware one."""
    from repro.core.trainer import make_algorithm
    fns = make_quadratic_fns()
    for name in ("dcliques", "d-cliques", "tv-dcliques"):
        with pytest.raises(ValueError, match="label-aware"):
            make_algorithm("dpsgd", fns, 4,
                           CommConfig(fabric=FabricConfig(topology=name)))
    # label-blind topologies still fall back fine
    algo = make_algorithm("dpsgd", fns, 4,
                          CommConfig(fabric=FabricConfig(topology="ring")))
    assert algo.schedule.at(0).name == "ring"


def test_skewscout_topology_mode_starts_on_configured_fabric():
    """With skewscout on, the configured topology must become a ladder
    rung and be the fabric the run starts on — not silently replaced by
    the nearest built-in rung."""
    from repro.configs.cnn_zoo import CNN_ZOO
    from repro.core.trainer import train_decentralized
    from repro.data.synthetic import synth_images
    ds = synth_images(120, seed=0, n_classes=3)
    parts = [(ds.x[i::4], ds.y[i::4]) for i in range(4)]
    comm = CommConfig(strategy="dpsgd",
                      fabric=FabricConfig(topology="random-matching"),
                      skewscout=True, travel_every=1000)  # never moves
    r = train_decentralized(CNN_ZOO["gn-lenet"], "dpsgd", parts,
                            (ds.x, ds.y), comm=comm, steps=3, batch=5,
                            eval_every=3)
    assert r.topology == "random-matching"
    # the configured fabric joined the ladder, and the label-aware rung
    # is reachable even though the starting topology is label-blind
    rungs = r.extras["topology_ladder"]
    assert "random-matching" in rungs
    assert any("dcliques" in name for name in rungs), rungs
    with pytest.raises(ValueError, match="theta_start_index"):
        train_decentralized(CNN_ZOO["gn-lenet"], "dpsgd", parts,
                            (ds.x, ds.y), comm=comm, steps=3, batch=5,
                            eval_every=3, theta_start_index=99)


def test_skewscout_topology_rung_switch_end_to_end():
    """The in-loop switch must move the gossip fabric AND the ledger in
    sync: under full label skew a ring start climbs to denser rungs,
    and every newly-activated link is charged re-wiring."""
    from repro.configs.cnn_zoo import CNN_ZOO
    from repro.core.trainer import train_decentralized
    from repro.data.synthetic import synth_images
    ds = synth_images(360, seed=0, n_classes=3)
    K = 6
    parts = []
    for k in range(K):                      # node k sees a single class
        i = np.where(ds.y == k % 3)[0][k // 3::2]
        parts.append((ds.x[i], ds.y[i]))
    comm = CommConfig(strategy="dpsgd",
                      fabric=FabricConfig(topology="ring",
                                          profile="geo-wan",
                                          rewire_floats=64.0),
                      skewscout=True, travel_every=3)
    r = train_decentralized(CNN_ZOO["gn-lenet"], "dpsgd", parts,
                            (ds.x, ds.y), comm=comm, steps=12, batch=5,
                            eval_every=12)
    moves = [(h.theta.name, h.new_theta.name) for h in r.skewscout_history]
    assert any(a != b for a, b in moves), moves     # the controller moved
    # the run ended on the fabric of the last move (algo side applied)
    assert r.topology == moves[-1][1]
    # ...and the ledger followed: switching to a denser rung activates
    # links the ring never had, each one booked as a re-wiring event
    assert r.extras["ledger"]["rewire_events"] > 0
    assert r.extras["ledger"]["rewire_floats"] > 0
