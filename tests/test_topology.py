"""Communication-fabric subsystem invariants: mixing matrices, D-Cliques
label balance, the D-PSGD/BSP equivalence on the complete graph, the
Pallas neighbor_mix kernel vs its dense oracle, and CommLedger
conservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CommConfig
from repro.core.algorithms.base import ModelFns, tree_size
from repro.core.algorithms.bsp import BSP
from repro.core.algorithms.dpsgd import DPSGD
from repro.kernels import ops, ref
from repro.topology import (LINK_PROFILES, CommLedger, build_topology,
                            d_cliques, fully_connected, hierarchical,
                            random_regular, ring, torus)

K = 4
DIM = 8


# ---------------------------------------------------------------------------
# graphs & mixing matrices
# ---------------------------------------------------------------------------

ALL_TOPOLOGIES = [fully_connected(5), ring(5), ring(2), torus(6), torus(9),
                  random_regular(8, 3, seed=0), hierarchical(6),
                  hierarchical(9, n_datacenters=3)]


@pytest.mark.parametrize("topo", ALL_TOPOLOGIES, ids=lambda t: t.name)
def test_mixing_matrix_doubly_stochastic_symmetric(topo):
    W = topo.mixing
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)
    assert (W >= -1e-12).all()
    # supported only on edges + diagonal
    edge_set = set(topo.edges)
    for i in range(topo.n_nodes):
        for j in range(i + 1, topo.n_nodes):
            if (i, j) not in edge_set:
                assert W[i, j] == 0.0


@pytest.mark.parametrize("topo", ALL_TOPOLOGIES, ids=lambda t: t.name)
def test_gossip_converges_to_consensus(topo):
    """W^t x -> mean(x): doubly-stochastic + connected + positive gap."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=topo.n_nodes)
    y = np.linalg.matrix_power(topo.mixing, 200) @ x
    np.testing.assert_allclose(y, x.mean(), atol=1e-6)
    assert topo.spectral_gap() > 0.01


def test_fully_connected_mixing_is_uniform():
    topo = fully_connected(5)
    np.testing.assert_allclose(topo.mixing, np.full((5, 5), 0.2), atol=1e-12)


def test_neighbor_arrays_reconstruct_mixing():
    topo = random_regular(8, 3, seed=1)
    idx, w, sw = topo.neighbor_arrays()
    K = topo.n_nodes
    R = np.zeros((K, K))
    for k in range(K):
        R[k, k] += sw[k]
        for d in range(idx.shape[1]):
            R[k, idx[k, d]] += w[k, d]
    np.testing.assert_allclose(R, topo.mixing, atol=1e-6)


def test_hierarchical_marks_wan_edges():
    topo = hierarchical(9, n_datacenters=3)
    wan = topo.wan_edge_indices()
    assert len(wan) == 3                      # gateway triangle
    assert len(topo.cliques) == 3
    lan = [e for e in range(len(topo.edges)) if e not in set(wan)]
    # LAN edges stay inside one datacenter
    groups = [set(c) for c in topo.cliques]
    for e in lan:
        i, j = topo.edges[e]
        assert any(i in g and j in g for g in groups)


def test_dcliques_label_histograms_near_uniform():
    """Exclusive-label partition over 10 nodes / 5 classes: each greedy
    clique should recover a (near-)uniform aggregate histogram."""
    n_nodes, n_classes = 10, 5
    hist = np.zeros((n_nodes, n_classes))
    for k in range(n_nodes):
        hist[k, k % n_classes] = 100
    topo = d_cliques(hist, seed=0)
    assert len(topo.cliques) >= 2
    glob = hist.sum(0) / hist.sum()
    for cq in topo.cliques:
        s = hist[list(cq)].sum(0)
        tv = 0.5 * np.abs(s / s.sum() - glob).sum()
        assert tv < 0.11, (cq, s)
    assert len(topo.wan_edge_indices()) >= 1   # inter-clique ring is WAN


def test_build_topology_registry():
    for name in ("full", "ring", "torus", "random", "geo-wan"):
        topo = build_topology(name, 6)
        assert topo.n_nodes == 6
    with pytest.raises(ValueError):
        build_topology("moebius", 6)
    with pytest.raises(AssertionError):
        build_topology("dcliques", 6)          # needs label_hist


# ---------------------------------------------------------------------------
# neighbor_mix kernel vs dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", [ring(5), random_regular(8, 3, seed=1),
                                  hierarchical(6), fully_connected(4)],
                         ids=lambda t: t.name)
@pytest.mark.parametrize("n", [37, 1000, 8192 + 13])
def test_neighbor_mix_matches_dense_ref(topo, n):
    x = jax.random.normal(jax.random.PRNGKey(0), (topo.n_nodes, n))
    idx, w, sw = topo.neighbor_arrays()
    out = ops.neighbor_mix(x, jnp.asarray(idx), jnp.asarray(w),
                           jnp.asarray(sw))
    expect = ref.neighbor_mix_ref(x, jnp.asarray(topo.mixing, jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# D-PSGD semantics
# ---------------------------------------------------------------------------

def make_quadratic_fns():
    def loss_and_grad(params, mstate, batch):
        diff = params["w"] - batch["target"]
        return 0.5 * jnp.sum(diff ** 2), {"w": diff}, mstate
    return ModelFns(loss_and_grad=loss_and_grad)


@pytest.fixture
def setup():
    fns = make_quadratic_fns()
    params = {"w": jnp.zeros((DIM,))}
    mstate = {"dummy": jnp.zeros((1,))}
    targets = np.stack([np.full(DIM, float(k + 1)) for k in range(K)])
    return fns, params, mstate, {"target": jnp.asarray(targets)}


def test_dpsgd_complete_graph_equals_bsp(setup):
    """Uniform mixing restores exact consensus every step, so the
    trajectory coincides with BSP (momentum included)."""
    fns, params, mstate, batch = setup
    bsp = BSP(fns, K, momentum=0.9, weight_decay=0.0)
    dp = DPSGD(fns, K, topology=fully_connected(K), momentum=0.9,
               weight_decay=0.0)
    sb, sd = bsp.init(params, mstate), dp.init(params, mstate)
    for t in range(10):
        sb, _ = bsp.step(sb, batch, jnp.float32(0.05), jnp.int32(t))
        sd, m = dp.step(sd, batch, jnp.float32(0.05), jnp.int32(t))
    wb = np.asarray(sb["params"]["w"])
    wd = np.asarray(sd["params"]["w"])
    np.testing.assert_allclose(wd, np.broadcast_to(wb, wd.shape), atol=1e-5)
    assert float(m["consensus_delta"]) < 1e-6


def test_dpsgd_two_node_ring_equals_bsp(setup):
    """K=2 ring mixing is exact averaging — the synthetic 2-node
    benchmark where dpsgd must reproduce BSP."""
    fns, params, mstate, _ = setup
    targets = np.stack([np.full(DIM, 1.0), np.full(DIM, 3.0)])
    batch = {"target": jnp.asarray(targets)}
    bsp = BSP(fns, 2, momentum=0.9, weight_decay=0.0)
    dp = DPSGD(fns, 2, topology=ring(2), momentum=0.9, weight_decay=0.0)
    sb, sd = bsp.init(params, mstate), dp.init(params, mstate)
    for t in range(20):
        sb, _ = bsp.step(sb, batch, jnp.float32(0.05), jnp.int32(t))
        sd, _ = dp.step(sd, batch, jnp.float32(0.05), jnp.int32(t))
    pb, _ = bsp.eval_params(sb)
    pd, _ = dp.eval_params(sd)
    np.testing.assert_allclose(np.asarray(pd["w"]), np.asarray(pb["w"]),
                               atol=1e-5)


def test_dpsgd_ring_reaches_consensus_on_mean_target(setup):
    """Sparse-graph gossip settles in an O(lr)-neighborhood of the
    global optimum (Lian et al. Thm 1) — shrink lr, shrink the error."""
    fns, params, mstate, batch = setup
    errs = {}
    for lr in (0.05, 0.01):
        dp = DPSGD(fns, K, topology=ring(K), momentum=0.0)
        s = dp.init(params, mstate)
        for t in range(1500):
            s, m = dp.step(s, batch, jnp.float32(lr), jnp.int32(t))
        w = np.asarray(s["params"]["w"])
        mean_target = np.mean([k + 1 for k in range(K)])
        errs[lr] = np.abs(w - mean_target).max()
    assert errs[0.05] < 0.05 and errs[0.01] < 0.01, errs
    assert errs[0.01] < errs[0.05]


def test_dpsgd_comm_floats_scale_with_degree(setup):
    fns, params, mstate, batch = setup
    per_model = tree_size(params)
    for topo in (ring(K), fully_connected(K)):
        dp = DPSGD(fns, K, topology=topo, momentum=0.0)
        s = dp.init(params, mstate)
        _, m = dp.step(s, batch, jnp.float32(0.05), jnp.int32(0))
        assert float(m["comm_floats"]) == pytest.approx(
            topo.mean_degree * per_model)


def test_dpsgd_kernel_and_dense_mix_agree(setup):
    fns, params, mstate, batch = setup
    topo = ring(K)
    dp_k = DPSGD(fns, K, topology=topo, momentum=0.9, use_kernel=True)
    dp_d = DPSGD(fns, K, topology=topo, momentum=0.9, use_kernel=False)
    sk, sd = dp_k.init(params, mstate), dp_d.init(params, mstate)
    for t in range(5):
        sk, _ = dp_k.step(sk, batch, jnp.float32(0.05), jnp.int32(t))
        sd, _ = dp_d.step(sd, batch, jnp.float32(0.05), jnp.int32(t))
    np.testing.assert_allclose(np.asarray(sk["params"]["w"]),
                               np.asarray(sd["params"]["w"]), atol=1e-5)


# ---------------------------------------------------------------------------
# CommLedger
# ---------------------------------------------------------------------------

def test_ledger_exchange_conserves_floats():
    topo = hierarchical(9, n_datacenters=3)
    led = CommLedger(topo, LINK_PROFILES["geo-wan"])
    led.record_exchange(1000.0)
    # every node's floats land somewhere: total == K * c, split LAN/WAN
    assert led.total_floats == pytest.approx(9 * 1000.0)
    assert led.lan_floats > 0 and led.wan_floats > 0
    assert led.total_floats == pytest.approx(
        led.lan_floats + led.wan_floats)


def test_ledger_gossip_traffic_per_edge():
    topo = ring(5)
    led = CommLedger(topo, LINK_PROFILES["uniform"])
    led.record_gossip(100.0)
    # each of the 5 edges carries the model both directions
    assert led.total_floats == pytest.approx(5 * 2 * 100.0)
    np.testing.assert_allclose(led.edge_traffic, 200.0)


def test_ledger_wan_pricing_dominates_under_geo_profile():
    topo = hierarchical(6)
    prof = LINK_PROFILES["geo-wan"]
    led = CommLedger(topo, prof)
    led.record_gossip(1000.0)
    wan_cost = led.wan_floats * prof.price_per_float("wan")
    assert wan_cost / led.priced_cost() > 0.9   # WAN bytes dominate
    # uniform profile: priced cost is proportional to raw floats
    led_u = CommLedger(topo, LINK_PROFILES["uniform"])
    led_u.record_gossip(1000.0)
    assert led_u.priced_cost() == pytest.approx(
        led_u.total_floats * LINK_PROFILES["uniform"].price_per_float("lan"))


def test_ledger_sim_time_slowest_link():
    topo = hierarchical(6)
    prof = LINK_PROFILES["geo-wan"]
    led = CommLedger(topo, prof)
    led.record_gossip(1000.0)
    expect = prof.wan_latency + 2000.0 / prof.wan_bandwidth
    assert led.sim_time_s == pytest.approx(expect)


def test_ledger_per_node_vector_exchange():
    topo = ring(4)
    led = CommLedger(topo, LINK_PROFILES["uniform"])
    led.record_exchange([100.0, 0.0, 0.0, 0.0])
    assert led.total_floats == pytest.approx(100.0)
    # node 0 has two incident edges, 50 floats each
    nz = led.edge_traffic[led.edge_traffic > 0]
    np.testing.assert_allclose(nz, 50.0)


# ---------------------------------------------------------------------------
# end-to-end: dpsgd through the trainer (full topology == BSP quality)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dpsgd_full_topology_matches_bsp_accuracy():
    """Acceptance: dpsgd on a fully-connected topology reproduces BSP's
    validation accuracy within 0.5pp on the synthetic 2-node benchmark."""
    from repro.configs.cnn_zoo import CNN_ZOO
    from repro.core.partition import partition_label_skew
    from repro.core.trainer import train_decentralized
    from repro.data.synthetic import synth_images
    ds = synth_images(1500, seed=0, noise=0.8, class_sep=0.35)
    val = synth_images(500, seed=99, noise=0.8, class_sep=0.35)
    idx = partition_label_skew(ds.y, 2, 0.0, seed=1)
    parts = [(ds.x[i], ds.y[i]) for i in idx]
    kw = dict(steps=200, batch=20, lr=0.02, eval_every=200)
    bsp = train_decentralized(CNN_ZOO["gn-lenet"], "bsp", parts,
                              (val.x, val.y), **kw)
    dp = train_decentralized(CNN_ZOO["gn-lenet"], "dpsgd", parts,
                             (val.x, val.y),
                             comm=CommConfig(strategy="dpsgd",
                                             topology="full"), **kw)
    assert abs(dp.val_acc - bsp.val_acc) < 0.005 + 1e-9, \
        (dp.val_acc, bsp.val_acc)
    assert dp.topology == "full"
    assert dp.extras["ledger"]["total_floats"] > 0


def test_trainer_rejects_invalid_eval_schedule():
    from repro.configs.cnn_zoo import CNN_ZOO
    from repro.core.trainer import train_decentralized
    from repro.data.synthetic import synth_images
    ds = synth_images(100, seed=0)
    parts = [(ds.x[:50], ds.y[:50]), (ds.x[50:], ds.y[50:])]
    with pytest.raises(ValueError, match="steps"):
        train_decentralized(CNN_ZOO["gn-lenet"], "bsp", parts,
                            (ds.x, ds.y), steps=0)
    with pytest.raises(ValueError, match="eval_every"):
        train_decentralized(CNN_ZOO["gn-lenet"], "bsp", parts,
                            (ds.x, ds.y), steps=10, eval_every=0)
